"""Offline fallback for `hypothesis`: deterministic sampled `given`.

The property tests prefer the real hypothesis package (shrinking, edge
cases, example database).  On network-less CI images where it is not
installed, this shim keeps them *running* instead of failing at
collection: `given` draws `max_examples` pseudo-random samples from each
strategy with a seed derived from the test name, so failures reproduce
across runs and machines.

Only the API surface the test suite uses is implemented: given,
settings (decorator + register_profile/load_profile),
strategies.{integers, floats, lists, sampled_from} and Strategy.filter.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:                         # offline image
        from _hypothesis_compat import given, settings
        from _hypothesis_compat import strategies as st
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib


class Unsatisfiable(Exception):
    """A .filter() predicate rejected every draw attempt."""


class Strategy:
    def __init__(self, draw_fn, describe: str = "strategy"):
        self._draw = draw_fn
        self._describe = describe

    def __repr__(self):
        return f"<{self._describe}>"

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def filter(self, predicate) -> "Strategy":
        def draw(rng, _base=self._draw):
            for _ in range(1000):
                v = _base(rng)
                if predicate(v):
                    return v
            raise Unsatisfiable(
                f"{self!r}.filter rejected 1000 consecutive draws")
        return Strategy(draw, f"{self._describe}.filter")

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng, _b=self._draw: fn(_b(rng)),
                        f"{self._describe}.map")


class strategies:
    """Namespace mirroring `hypothesis.strategies`."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value),
                        f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(lambda rng: rng.uniform(min_value, max_value),
                        f"floats({min_value}, {max_value})")

    @staticmethod
    def sampled_from(elements) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: rng.choice(elements),
                        f"sampled_from({elements!r:.40s})")

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return Strategy(draw, f"lists({elements!r}, {min_size}, {max_size})")

class settings:
    """Decorator recording per-test overrides + profile store."""
    _profiles: dict = {"default": {"max_examples": 50}}
    _active: dict = _profiles["default"]

    def __init__(self, max_examples: int | None = None, deadline=None,
                 **_ignored):
        self._overrides = {}
        if max_examples is not None:
            self._overrides["max_examples"] = max_examples

    def __call__(self, fn):
        fn._hypothesis_compat_settings = self._overrides
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int | None = None,
                         deadline=None, **_ignored):
        cls._profiles[name] = ({"max_examples": max_examples}
                               if max_examples is not None else {})

    @classmethod
    def load_profile(cls, name: str):
        cls._active = {**cls._profiles["default"], **cls._profiles[name]}


def given(*strats: Strategy):
    """Run the test once per example with deterministically drawn args."""
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit inside @given (attribute on fn) or outside
            # it (attribute on this wrapper) — real hypothesis allows both.
            cfg = {**settings._active,
                   **getattr(fn, "_hypothesis_compat_settings", {}),
                   **wrapper.__dict__.get("_hypothesis_compat_settings", {})}
            n = cfg.get("max_examples") or 50
            seed0 = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random(seed0 + i)
                vals = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i}): "
                        f"{fn.__name__}{tuple(vals)!r}") from e
        # hide the original signature: pytest must not resolve the
        # strategy-bound parameters as fixtures (real hypothesis does the
        # same).  `self` is supplied by bound-method dispatch, not by name.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorate
