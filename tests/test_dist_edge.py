"""repro.dist edge cases beyond the seed contract: degenerate shapes,
unknown logical axes, awkward device counts, context lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import FakeMesh
from repro.dist import mesh as mesh_lib
from repro.dist import sharding as shd
from repro.models.config import ParamDef

MESH = FakeMesh((16, 16), ("data", "model"))


def test_zero_size_dim_replicates():
    # empty buffers (elastic scale-to-zero shards) must not claim axes
    assert shd.logical_to_spec(("embed", "mlp"), (0, 14336),
                               shd.train_rules(), MESH) == P(None, "model")
    assert shd.logical_to_spec(("mlp",), (0,), shd.train_rules(),
                               MESH) == P()


def test_one_dim_param():
    # 1-d norm scale: FSDP shards it over data when divisible
    assert shd.logical_to_spec(("embed",), (4096,), shd.train_rules(),
                               MESH) == P("data")
    assert shd.logical_to_spec(("embed",), (100,), shd.train_rules(),
                               MESH) == P()


def test_scalar_param():
    assert shd.logical_to_spec((), (), shd.train_rules(), MESH) == P()


def test_unknown_logical_axis_raises():
    with pytest.raises(shd.UnknownLogicalAxisError, match="warp_drive"):
        shd.logical_to_spec(("warp_drive",), (64,), shd.train_rules(), MESH)
    with pytest.raises(KeyError):          # it is also a KeyError
        shd.logical_to_spec(("batch", "typo"), (8, 8), shd.serve_rules(),
                            MESH)


def test_rank_mismatch_raises():
    with pytest.raises(ValueError, match="rank"):
        shd.logical_to_spec(("embed",), (8, 8), shd.train_rules(), MESH)


def test_quantum_partial_unit_blocks():
    # dim not divisible by the quantum itself: never sharded
    r = shd.train_rules(quantum={"heads": 128})
    assert shd.logical_to_spec(("heads",), (2048 + 64,), r, MESH) == P()


@pytest.mark.parametrize("n", [1, 3, 6, 8, 12, 48, 100, 256])
def test_spec_for_arbitrary_counts(n):
    s = mesh_lib.spec_for(n)
    assert s.num_devices == n
    assert s.axes == ("data", "model")
    sm = mesh_lib.spec_for(n, multi_pod=True)
    assert sm.num_devices == n
    assert "pod" in sm.axes


def test_spec_for_256_matches_single_pod():
    assert mesh_lib.spec_for(256).shape == mesh_lib.SINGLE_POD.shape


def test_spec_for_rejects_nonpositive():
    with pytest.raises(ValueError):
        mesh_lib.spec_for(0)


def test_with_overrides_does_not_mutate():
    base = shd.train_rules()
    base.with_overrides(mlp=None, seq="model")
    assert base.physical("mlp") == "model"
    assert base.physical("seq") is None


def test_spec_tree_handles_nested_defs():
    defs = {"a": ParamDef((4096, 14336), ("embed", "mlp")),
            "nested": {"b": ParamDef((), (), "zeros", jnp.int32)}}
    tree = shd.spec_tree(defs, shd.train_rules(), MESH)
    assert tree["a"] == P("data", "model")
    assert tree["nested"]["b"] == P()


def test_constrain_act_noop_without_context():
    shd.set_activation_context(None, None)
    x = jnp.ones((2, 8, 16))
    assert shd.constrain_act(x) is x


def test_constrain_act_applies_on_real_mesh():
    # 1-device mesh: the constraint must at least round-trip values
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(dev, ("data", "model"))
    rules = shd.train_rules()
    try:
        shd.set_activation_context(rules, mesh)
        x = jnp.arange(2 * 4 * 8, dtype=jnp.float32).reshape(2, 4, 8)
        y = jax.jit(lambda a: shd.constrain_act(a) * 2)(x)
        assert jnp.array_equal(y, x * 2)
    finally:
        shd.set_activation_context(None, None)


def test_batch_partial_fold_uses_pod_only():
    # batch divides the pod axis but not pod*data: folds over 'pod' alone
    pod = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    s = shd.logical_to_spec(("batch", "seq"), (2, 128), shd.train_rules(),
                            pod)
    assert s == P("pod")
