"""Measured block-shape autotuner (ISSUE 8): persistence determinism,
plan-resolution integration, and the loud analysis gate.

  * canonical round trip: save -> load -> save is byte-identical, and
    entry order cannot change the bytes;
  * cold miss -> ``select_block_shapes`` fallback, recorded as
    ``block_source='heuristic'`` in ``ExecutionPlan.describe()`` and
    logged once per cell (never silent);
  * warm hit -> the TABLE's blocks land in the plan,
    ``block_source='autotune'``; explicit bm/bn/bk kwargs still win
    (``block_source='pinned'``);
  * a doctored table fails loudly in the analysis pass (`make
    analyze`, AT001/AT002/AT003/AT005) and in the bench schema gate,
    while the RUNTIME loader degrades to the heuristic with a warning
    — a serving box keeps serving;
  * the tracked repo-root BENCH_autotune.json is valid, canonical, and
    actually consulted by plan resolution for its sweep cells.
"""
import json
import logging
import os

import pytest

from repro.kernels import autotune, plan_matmul
from repro.kernels.ternary_matmul import select_block_shapes
from repro.analysis import autotune_table as autotune_pass

TRACKED = os.path.join(os.path.dirname(__file__), "..",
                       "BENCH_autotune.json")

# one valid synthetic cell OUTSIDE the tuning sweep (so the tracked
# table can never satisfy it): float/base3, aligned, VMEM-feasible
CELL = dict(m=8, k=256, n=256, phase="decode", platform="cpu",
            packing="base3", domain="float")
ENTRY = dict(CELL, blocks=[8, 256, 256], time_s=1e-3,
             heuristic_blocks=[8, 128, 256], heuristic_time_s=2e-3)


def _write(tmp_path, entries, name="table.json"):
    path = tmp_path / name
    path.write_text(autotune.canonical_bytes(entries))
    return str(path)


@pytest.fixture
def table_env(tmp_path, monkeypatch):
    """Point $REPRO_AUTOTUNE_TABLE at a tmp table and hand back a
    setter; restores + reloads afterwards (reload drops the plan cache
    so no stale measured blocks leak across tests)."""
    def use(path):
        monkeypatch.setenv(autotune.ENV_VAR, path)
        autotune.reload_table()
        return path
    yield use
    monkeypatch.delenv(autotune.ENV_VAR, raising=False)
    autotune.reload_table()


# ------------------------------------------------- persistence


class TestPersistence:
    def test_round_trip_is_byte_identical(self, tmp_path):
        path = _write(tmp_path, [ENTRY])
        first = open(path).read()
        again = autotune.save_table(autotune.load_entries(path),
                                    str(tmp_path / "again.json"))
        assert open(again).read() == first

    def test_entry_order_cannot_change_the_bytes(self):
        e2 = dict(ENTRY, m=16, blocks=[16, 256, 256])
        assert (autotune.canonical_bytes([ENTRY, e2])
                == autotune.canonical_bytes([e2, ENTRY]))

    def test_save_refuses_invalid_entries(self, tmp_path):
        bad = dict(ENTRY, blocks=[100, 256, 256])     # unaligned bm
        with pytest.raises(ValueError, match="refusing to save"):
            autotune.save_table([bad], str(tmp_path / "bad.json"))

    def test_empty_env_var_disables_the_table(self, table_env):
        table_env("")
        assert autotune.lookup_blocks(**CELL) is None


# ------------------------------------- plan-resolution integration


class TestPlanIntegration:
    def test_cold_miss_falls_back_to_heuristic(self, table_env, caplog):
        table_env("")                   # no table at all
        with caplog.at_level(logging.INFO, "repro.kernels.autotune"):
            plan = plan_matmul((CELL["m"], CELL["k"], CELL["n"]),
                               CELL["phase"], backend="pallas",
                               packing=CELL["packing"])
        d = plan.describe()
        assert d["block_source"] == "heuristic"
        assert tuple(d["blocks"]) == select_block_shapes(
            CELL["m"], CELL["k"], CELL["n"], CELL["packing"],
            domain=CELL["domain"])
        assert any("autotune table miss" in r.message
                   for r in caplog.records)

    def test_warm_hit_resolves_the_table_blocks(self, tmp_path,
                                                table_env):
        table_env(_write(tmp_path, [ENTRY]))
        plan = plan_matmul((CELL["m"], CELL["k"], CELL["n"]),
                           CELL["phase"], backend="pallas",
                           packing=CELL["packing"])
        d = plan.describe()
        assert d["block_source"] == "autotune"
        assert list(d["blocks"]) == ENTRY["blocks"]

    def test_explicit_blocks_pin_over_the_table(self, tmp_path,
                                                table_env):
        table_env(_write(tmp_path, [ENTRY]))
        plan = plan_matmul((CELL["m"], CELL["k"], CELL["n"]),
                           CELL["phase"], backend="pallas",
                           packing=CELL["packing"], bm=8, bn=128, bk=256)
        d = plan.describe()
        assert d["block_source"] == "pinned"
        assert tuple(d["blocks"]) == (8, 128, 256)

    def test_doctored_table_degrades_to_heuristic(self, tmp_path,
                                                  table_env, caplog):
        bad = dict(ENTRY, blocks=[100, 256, 256])     # unaligned bm
        path = tmp_path / "doctored.json"
        path.write_text(json.dumps(
            {"version": autotune.TABLE_VERSION, "entries": [bad]}))
        with caplog.at_level(logging.WARNING, "repro.kernels.autotune"):
            table_env(str(path))
            plan = plan_matmul((CELL["m"], CELL["k"], CELL["n"]),
                               CELL["phase"], backend="pallas",
                               packing=CELL["packing"])
        assert plan.describe()["block_source"] == "heuristic"
        assert any("fails validation" in r.message
                   for r in caplog.records)


# ------------------------------------------------ the loud gate


class TestAnalysisGate:
    def _findings(self, tmp_path, entries, doctor=None):
        payload = json.loads(autotune.canonical_bytes(entries))
        if doctor:
            doctor(payload)
        path = tmp_path / "t.json"
        path.write_text(json.dumps(payload, indent=2, sort_keys=True)
                        + "\n")
        return autotune_pass.run(table_path=str(path))

    def test_missing_table_is_a_finding(self, tmp_path):
        fs = autotune_pass.run(table_path=str(tmp_path / "absent.json"))
        assert [f.rule for f in fs] == ["AT004"]

    def test_structure_violation_at001(self, tmp_path):
        fs = self._findings(tmp_path, [ENTRY],
                            lambda p: p.__setitem__("version", 99))
        assert any(f.rule == "AT001" for f in fs)

    def test_alignment_violation_at002(self, tmp_path):
        fs = self._findings(tmp_path,
                            [dict(ENTRY, blocks=[8, 100, 256])])
        assert any(f.rule == "AT002" for f in fs)

    def test_duplicate_cell_at003(self, tmp_path):
        dup = dict(ENTRY, blocks=[8, 128, 256])
        fs = self._findings(tmp_path, [ENTRY, dup])
        assert any(f.rule == "AT003" for f in fs)

    def test_non_canonical_serialization_at005(self, tmp_path):
        path = tmp_path / "t.json"
        payload = json.loads(autotune.canonical_bytes(
            autotune.load_entries(TRACKED)))
        path.write_text(json.dumps(payload))      # compact, no newline
        fs = autotune_pass.run(table_path=str(path))
        assert any(f.rule == "AT005" for f in fs)

    def test_bench_schema_gate_shares_the_contract(self, tmp_path):
        from benchmarks import schema
        bad = {"version": autotune.TABLE_VERSION,
               "entries": [dict(ENTRY, blocks=[8, 100, 256])]}
        path = tmp_path / "BENCH_autotune.json"
        path.write_text(json.dumps(bad))
        errors = schema.validate_file(str(path))
        assert errors and any("AT002" in e for e in errors)


# ------------------------------------------- the tracked artifact


class TestTrackedTable:
    def test_tracked_table_is_clean(self):
        assert autotune_pass.run() == []

    def test_sweep_cells_resolve_from_the_table(self):
        import jax
        platform = jax.default_backend()
        entries = [e for e in autotune.load_entries(TRACKED)
                   if e["platform"] == platform]
        assert entries, f"no {platform} entries in BENCH_autotune.json"
        e = entries[0]
        autotune.reload_table()
        plan = plan_matmul((e["m"], e["k"], e["n"]), e["phase"],
                           backend="pallas", packing=e["packing"],
                           domain=e["domain"])
        d = plan.describe()
        assert d["block_source"] == "autotune"
        assert list(d["blocks"]) == e["blocks"]

    def test_measured_candidates_satisfy_the_invariants(self):
        # every candidate the tuner races must individually pass the
        # same invariants the gate enforces on the winner
        cands = autotune.candidate_blocks(8, 1024, 1024, "trit2",
                                          "float")
        entries = [dict(ENTRY, k=1024, n=1024, packing="trit2",
                        blocks=list(b)) for b in cands]
        for i, e in enumerate(entries):   # distinct cells: vary m
            e["m"] = 8 * (i + 1)
        assert autotune.validate_table(json.loads(
            autotune.canonical_bytes(entries))) == []
