"""Paged, prefix-shared KV for the slot pool (ISSUE 5 acceptance):

  * bitwise token parity: the PagedScheduler emits identical tokens to
    the dense-pool Scheduler AND both PR 2 bucket drivers (on-device
    loop, legacy step loop) for mixed prompt lengths, including prompts
    that do not align to page boundaries;
  * page reuse isolation: a page freed on EOS/retire and reallocated to
    a later request never leaks stale KV (every request matches its
    solo batch-1 reference);
  * prefix sharing: pages mapped shared (hashed token prefix already in
    the pool) give IDENTICAL tokens to private copies, including
    cross-length shared prefixes; refcounts return shared pages to the
    free list only when the last reference drops;
  * capacity discipline: admission reserves pages all-or-nothing and
    DEFERS (never OOMs mid-decode) when the pool is exhausted — every
    request still completes;
  * the kv_layout plan/request seam: paged plans resolve only on
    backends declaring the capability, and a dense-only backend is
    rejected loudly;
  * attend()/flash_attention() accept PagedKV gather-views bitwise;
  * the sharded slot pool (8 fake devices) emits identical tokens
    (slow subprocess test);
  * the serve_paged bench schema gate.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.models import paged_kv
from repro.serve import (PagedScheduler, Request, Scheduler, ServeEngine)

jax.config.update("jax_platform_name", "cpu")


def _setup(arch="internlm2-1.8b", dtype=jnp.float32, **over):
    cfg = dataclasses.replace(configs.smoke(arch), dtype=dtype, **over)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, specs):
    """specs: list of (uid, prompt_len, max_new[, eos_id]); prompt
    contents keyed by uid % 3 so repeated keys share full prompts."""
    key = jax.random.key(1)
    out = []
    for spec in specs:
        uid, plen, max_new = spec[:3]
        eos = spec[3] if len(spec) > 3 else -1
        prompt = jax.random.randint(jax.random.fold_in(key, uid % 3),
                                    (plen,), 0, cfg.vocab_size)
        out.append(Request(uid=uid, prompt=prompt, max_new=max_new,
                           eos_id=eos))
    return out


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    return {r.uid: list(r.out_tokens) for r in engine.run()}


# ------------------------------------------------- token parity

def test_paged_tokens_match_all_drivers():
    """PagedScheduler == dense Scheduler == device bucket loop ==
    legacy step loop, bitwise, on mixed prompt lengths (page-aligned
    and not) and mixed budgets."""
    cfg, model, params = _setup()
    specs = [(0, 8, 5), (1, 12, 3), (2, 6, 7), (3, 16, 4), (4, 9, 1)]

    outs = []
    for engine in (
        PagedScheduler(model, params, capacity=32, slots=4, chunk=3,
                       page_size=4),
        Scheduler(model, params, capacity=32, slots=4, chunk=3),
        ServeEngine(model, params, capacity=32, max_batch=1,
                    on_device_loop=True),
        ServeEngine(model, params, capacity=32, max_batch=1,
                    on_device_loop=False),
    ):
        outs.append(_run(engine, _requests(cfg, specs)))
    assert outs[0] == outs[1] == outs[2] == outs[3]
    assert all(len(outs[0][uid]) == mn for uid, _, mn in specs)


def test_paged_page_size_invariance():
    """The page size is a storage choice, not a semantics choice: every
    page size yields the same tokens."""
    cfg, model, params = _setup()
    specs = [(0, 10, 4), (1, 7, 6), (2, 13, 3)]
    ref = _run(Scheduler(model, params, capacity=32, slots=3, chunk=4),
               _requests(cfg, specs))
    for ps in (1, 3, 8, 32):
        got = _run(PagedScheduler(model, params, capacity=32, slots=3,
                                  chunk=4, page_size=ps),
                   _requests(cfg, specs))
        assert got == ref, f"page_size={ps}"


# ------------------------------------------------- reuse isolation

def test_recycled_pages_never_leak_stale_kv():
    """More requests than the pool can hold at once: pages freed on
    retire are reallocated to later requests.  Every request must match
    its solo batch-1 run — stale KV in a recycled page would diverge."""
    cfg, model, params = _setup()
    specs = [(i, 6 + 3 * (i % 3), 3 + (i % 4)) for i in range(8)]
    sch = PagedScheduler(model, params, capacity=32, slots=2, chunk=3,
                         page_size=4)
    got = _run(sch, _requests(cfg, specs))
    assert sorted(got) == [s[0] for s in specs]
    assert sch.pages_in_use == 0            # every page returned
    assert sch.allocator.peak_in_use > 0

    for spec in specs:
        eng = ServeEngine(model, params, capacity=32, max_batch=1)
        solo = _run(eng, _requests(cfg, [spec]))
        assert got[spec[0]] == solo[spec[0]], \
            f"recycled page corrupted request {spec[0]}"


def test_eos_frees_pages_for_reuse():
    cfg, model, params = _setup()
    prompt = jnp.zeros((4,), jnp.int32)
    from repro.serve import make_prefill_step
    pre = make_prefill_step(model, 32)
    tok, _ = pre(params, {"tokens": prompt[None]})
    eos = int(tok[0])
    sch = PagedScheduler(model, params, capacity=16, slots=1, chunk=4,
                         page_size=4, num_pages=5)
    sch.submit(Request(uid=0, prompt=prompt, max_new=8, eos_id=eos))
    sch.submit(Request(uid=1, prompt=jnp.ones((4,), jnp.int32),
                       max_new=3))
    done = {r.uid: r for r in sch.run()}
    assert len(done[0].out_tokens) == 1      # tok0 == eos: stops at once
    assert len(done[1].out_tokens) == 3      # pages freed and reused
    assert sch.pages_in_use == 0


def test_pool_exhaustion_defers_admission():
    """A page pool too small for two concurrent requests serializes
    them (all-or-nothing reservation) instead of failing mid-decode."""
    cfg, model, params = _setup()
    # each request: prompt 8 + max_new 4 -> positions 0..10 -> 3 pages
    specs = [(i, 8, 4) for i in range(4)]
    sch = PagedScheduler(model, params, capacity=16, slots=4, chunk=4,
                         page_size=4, num_pages=4,      # 3 usable pages
                         share_prefix=False)
    got = _run(sch, _requests(cfg, specs))
    ref = _run(Scheduler(model, params, capacity=16, slots=4, chunk=4),
               _requests(cfg, specs))
    assert got == ref
    assert sch.allocator.peak_in_use <= 3


def test_request_exceeding_capacity_fails_loudly():
    cfg, model, params = _setup()
    sch = PagedScheduler(model, params, capacity=8, slots=1, chunk=2,
                         page_size=4)
    sch.submit(Request(uid=0, prompt=jnp.zeros((8,), jnp.int32),
                       max_new=8))
    with pytest.raises(ValueError, match="needs .* pages"):
        sch.run()


def test_request_exceeding_whole_pool_fails_loudly():
    """A request no empty pool could ever privately satisfy must raise,
    not busy-spin on deferred admission forever."""
    cfg, model, params = _setup()
    sch = PagedScheduler(model, params, capacity=32, slots=1, chunk=2,
                         page_size=4, num_pages=4)      # 3 usable pages
    sch.submit(Request(uid=0, prompt=jnp.zeros((8,), jnp.int32),
                       max_new=8))                      # needs 4 pages
    with pytest.raises(ValueError, match="usable pages"):
        sch.run()


# ------------------------------------------------- prefix sharing

def test_prefix_sharing_matches_private_copies():
    """Shared read-only pages produce the same tokens as private
    copies (share_prefix=False) and as the dense pool — and actually
    fire on identical and cross-length prefixes."""
    cfg, model, params = _setup()
    base = jax.random.randint(jax.random.key(7), (12,), 0,
                              cfg.vocab_size)
    def reqs():
        return [Request(uid=0, prompt=base, max_new=6),
                Request(uid=1, prompt=base, max_new=4),
                Request(uid=2, prompt=base[:9], max_new=4),
                Request(uid=3, prompt=jnp.concatenate(
                    [base[:8], base[:4]]), max_new=3)]

    dense = _run(Scheduler(model, params, capacity=32, slots=4, chunk=4),
                 reqs())
    shared = PagedScheduler(model, params, capacity=32, slots=4, chunk=4,
                            page_size=4)
    got = _run(shared, reqs())
    private = PagedScheduler(model, params, capacity=32, slots=4,
                             chunk=4, page_size=4, share_prefix=False)
    got_priv = _run(private, reqs())

    assert got == got_priv == dense
    assert shared.allocator.prefix_hits > 0
    assert private.allocator.prefix_hits == 0
    assert 0.0 < shared.prefix_hit_rate <= 1.0
    # shared pages cost the pool less than private copies
    assert shared.allocator.peak_in_use < private.allocator.peak_in_use
    # every reference released: the registry is empty again
    assert shared.pages_in_use == 0


def test_allocator_refcounts_and_peak():
    a = paged_kv.PageAllocator(num_pages=6, page_size=4)
    ids = a.alloc(3)
    assert ids is not None and len(set(ids)) == 3 and 0 not in ids
    assert a.pages_in_use == 3 and a.peak_in_use == 3
    assert a.alloc(3) is None                # all-or-nothing
    assert a.pages_in_use == 3               # failed alloc left no trace
    a.register_prefix(("k",), ids[0])
    assert a.lookup_prefix(("k",)) == ids[0]     # refcount 2
    assert a.lookup_prefix(("missing",)) is None
    a.release([ids[0]])
    assert a.pages_in_use == 3               # still referenced
    a.release([ids[0], ids[1], ids[2]])
    assert a.pages_in_use == 0
    assert a.lookup_prefix(("k",)) is None   # unregistered on last free
    assert a.peak_in_use == 3
    assert a.prefix_hits == 1 and a.prefix_lookups == 3


# ------------------------------------------------- kv_layout plan seam

def test_paged_plan_capability():
    from repro.kernels import (BackendSpec, plan_matmul, register_backend,
                               unregister_backend)
    p = plan_matmul((4, 64, 32), kv_layout="paged", backend="xla")
    assert p.kv_layout == "paged"
    assert p.describe()["kv_layout"] == "paged"
    # plans default to dense and the two layouts cache separately
    assert plan_matmul((4, 64, 32), backend="xla").kv_layout == "dense"
    with pytest.raises(ValueError, match=r"'dense', 'paged'"):
        plan_matmul((4, 64, 32), kv_layout="ragged")

    register_backend(BackendSpec(
        name="dense_only", ops=frozenset({"ternary"}),
        domains=frozenset({"float"}),
        packings=frozenset({"base3", "trit2"}),
        platforms=frozenset({"cpu", "tpu"}), priority=1,
        runner=lambda plan, x, w: x,
        kv_layouts=frozenset({"dense"})))
    try:
        with pytest.raises(ValueError,
                           match=r"does not support kv layout 'paged'"):
            plan_matmul((4, 64, 32), backend="dense_only",
                        kv_layout="paged")
        assert plan_matmul((4, 64, 32), kv_layout="paged").backend \
            != "dense_only"
    finally:
        unregister_backend("dense_only")


def test_paged_scheduler_resolves_paged_plans():
    """A ternary CIM config under the PagedScheduler is re-resolved
    with kv_layout='paged', so dense() plans under it carry the paged
    capability request."""
    from repro.core.cim_linear import CIMConfig, ternarize_params
    cfg, model, params = _setup()
    cim = CIMConfig(mode="ternary", packing="base3")
    pparams = ternarize_params(params, cim)
    sch = PagedScheduler(model, pparams, capacity=32, slots=2, chunk=3,
                         page_size=4, cim=cim)
    assert sch.cim.kv_layout == "paged"
    assert sch.cim.backend != "auto"
    got = _run(sch, _requests(cfg, [(0, 8, 3), (1, 6, 4)]))
    dense = _run(Scheduler(model, pparams, capacity=32, slots=2, chunk=3,
                           cim=cim), _requests(cfg, [(0, 8, 3),
                                                     (1, 6, 4)]))
    assert got == dense


# ------------------------------------------------- attend() wiring

def test_attend_accepts_paged_views_bitwise():
    from repro.models.attention import attend, flash_attention
    cfg = configs.smoke("internlm2-1.8b")
    key = jax.random.key(3)
    b, t, kvh, hd = 2, 16, cfg.num_kv_heads, cfg.hd
    ps = 4
    q = jax.random.normal(jax.random.fold_in(key, 0),
                          (b, 4, cfg.num_heads, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kvh, hd),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kvh, hd),
                          jnp.float32)
    # scatter the dense k/v into a shuffled page pool, per batch row
    perm = np.array([[3, 0, 2, 1], [5, 7, 4, 6]], np.int32)
    pool_k = jnp.zeros((9, ps, kvh, hd), jnp.float32)
    pool_v = jnp.zeros((9, ps, kvh, hd), jnp.float32)
    for row in range(b):
        for j in range(t // ps):
            pool_k = pool_k.at[perm[row, j]].set(
                k[row, j * ps:(j + 1) * ps])
            pool_v = pool_v.at[perm[row, j]].set(
                v[row, j * ps:(j + 1) * ps])
    pk = paged_kv.PagedKV(pool_k, jnp.asarray(perm))
    pv = paged_kv.PagedKV(pool_v, jnp.asarray(perm))

    np.testing.assert_array_equal(
        np.asarray(paged_kv.materialize(pk)), np.asarray(k))
    got = attend(q, pk, pv, cfg, causal=False)
    want = attend(q, k, v, cfg, causal=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_f = flash_attention(q, pk, pv, cfg, causal=False, chunk=8)
    want_f = flash_attention(q, k, v, cfg, causal=False, chunk=8)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))


def test_non_transformer_families_reject_paged():
    cfg = configs.smoke("xlstm-125m")
    model = registry.build(dataclasses.replace(cfg, dtype=jnp.float32))
    assert not model.supports_paged_kv
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="does not support paged KV"):
        PagedScheduler(model, params, capacity=16, slots=1, chunk=2)


def test_sliding_window_models_reject_paged():
    """Sliding-window decode uses a rolling cache (slot = pos % window,
    engaged only when cap == window); a page-gathered view's capacity
    would silently disarm the window mask and diverge from the dense
    pool — so those models must be refused, not mis-served."""
    cfg, model, params = _setup("mixtral-8x7b")       # sliding_window=16
    assert cfg.sliding_window > 0
    assert not model.supports_paged_kv
    with pytest.raises(ValueError, match="does not support paged KV"):
        PagedScheduler(model, params, capacity=32, slots=1, chunk=2)
    # the same config without the window pages fine
    cfg2, model2, params2 = _setup("mixtral-8x7b", sliding_window=0)
    assert model2.supports_paged_kv
    got = _run(PagedScheduler(model2, params2, capacity=32, slots=2,
                              chunk=3, page_size=4),
               _requests(cfg2, [(0, 8, 3), (1, 6, 4)]))
    ref = _run(Scheduler(model2, params2, capacity=32, slots=2, chunk=3),
               _requests(cfg2, [(0, 8, 3), (1, 6, 4)]))
    assert got == ref


# ------------------------------------------------- sharded pool

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
from repro import configs
from repro.dist import mesh as mesh_lib, sharding as shd
from repro.models import registry
from repro.serve import PagedScheduler, Request

cfg = dataclasses.replace(configs.smoke("internlm2-1.8b"),
                          dtype=jnp.float32)
model = registry.build(cfg)
params = model.init(jax.random.key(0))
key = jax.random.key(1)

def reqs():
    return [Request(uid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (8,), 0, cfg.vocab_size),
                    max_new=3 + i)
            for i in range(4)]

def run(spmd_axes, rules=None, mesh=None):
    shd.set_activation_context(rules, mesh)
    sch = PagedScheduler(model, params, capacity=32, slots=4, chunk=3,
                         page_size=4, spmd_axes=spmd_axes)
    for r in reqs():
        sch.submit(r)
    return {r.uid: list(r.out_tokens) for r in sch.run()}

ref = run(None)

mesh = mesh_lib.make_mesh(mesh_lib.MeshSpec((2, 4), ("data", "model")))
rules = shd.rules_for(cfg, "serve")
got = run(shd.slot_spmd_axes(rules, mesh, 4), rules, mesh)

print(json.dumps({"identical": got == ref,
                  "devices": jax.device_count(),
                  "page_axes": str(shd.page_spmd_axes(rules, mesh, 33)),
                  "spmd_axes": str(shd.slot_spmd_axes(rules, mesh, 4))}))
"""


@pytest.mark.slow
def test_sharded_paged_pool_matches_unsharded():
    """The paged slot pool under slot-axis SPMD sharding (8 fake
    devices) must not change a single token."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["spmd_axes"] == "data"
    assert out["identical"]


# ------------------------------------------------- fused attention

def test_fused_attn_token_parity():
    """fused_attn=True (the Pallas page-table read) == fused_attn=False
    (the slot_view gather) == the dense pool, bitwise, on unaligned
    prompt lengths — the ISSUE 8 regression currency."""
    cfg, model, params = _setup()
    specs = [(0, 8, 6), (1, 6, 8), (2, 13, 5)]
    fused = PagedScheduler(model, params, capacity=32, slots=2, chunk=3,
                           page_size=4, fused_attn=True)
    assert fused.attn_plan is not None
    assert fused.attn_plan.backend == "paged_attn"
    assert fused.attn_plan.describe()["kv_layout"] == "paged"
    gather = PagedScheduler(model, params, capacity=32, slots=2, chunk=3,
                            page_size=4, fused_attn=False)
    assert gather.attn_plan is None
    got_f = _run(fused, _requests(cfg, specs))
    got_g = _run(gather, _requests(cfg, specs))
    dense = _run(Scheduler(model, params, capacity=32, slots=2, chunk=3),
                 _requests(cfg, specs))
    assert got_f == got_g == dense


def test_fused_attn_auto_falls_back_on_interpret_platform(caplog):
    """'auto' must not serve wallclock through the interpret-mode
    emulation: on a platform without a real lowering it takes the
    gather path and says why."""
    import logging
    from repro.kernels import plan_matmul
    probe = plan_matmul((16 * 2, 64, 32), "decode", op="attention",
                        domain="float", kv_layout="paged")
    if not probe.interpret:
        pytest.skip("platform lowers the fused kernel natively")
    cfg, model, params = _setup()
    with caplog.at_level(logging.INFO, "repro.serve.engine"):
        sch = PagedScheduler(model, params, capacity=32, slots=2,
                             chunk=3, page_size=4)       # fused_attn auto
    assert sch.attn_plan is None
    assert any("interpret" in r.getMessage() for r in caplog.records)


def test_fused_attn_true_rejects_incapable_pools():
    """fused_attn=True must raise loudly when no backend can serve the
    pool — int8 KV carries scale pages the fused read does not consume."""
    cfg, model, params = _setup(kv_cache_dtype="int8")
    with pytest.raises(ValueError, match="int8 KV pool"):
        PagedScheduler(model, params, capacity=32, slots=2, chunk=3,
                       page_size=4, fused_attn=True)


def test_fused_attn_auto_moe_fallback(caplog):
    """'auto' keeps the gather graph under MoE routing (top-k amplifies
    the kernel's f32 reassociation into token divergence) — logged."""
    import logging
    cfg, model, params = _setup("mixtral-8x7b", sliding_window=0)
    assert cfg.num_experts > 0
    with caplog.at_level(logging.INFO, "repro.serve.engine"):
        sch = PagedScheduler(model, params, capacity=32, slots=2,
                             chunk=3, page_size=4)
    assert sch.attn_plan is None
    assert any("MoE" in r.getMessage() for r in caplog.records)


def test_attention_plan_capability():
    """op='attention' resolves through the registry like any other op:
    pallas wins on capable platforms, dense layout and non-float
    domains have no backend and fail loudly."""
    from repro.kernels import plan_matmul
    plan = plan_matmul((32, 64, 128), "decode", op="attention",
                       domain="float", kv_layout="paged")
    assert plan.backend == "paged_attn"
    assert plan.describe()["blocks"] is None       # needs_blocks False
    ref = plan_matmul((32, 64, 128), "decode", op="attention",
                      domain="float", kv_layout="paged",
                      backend="paged_attn_ref")
    assert ref.backend == "paged_attn_ref"
    with pytest.raises(ValueError, match="no registered backend"):
        plan_matmul((32, 64, 128), "decode", op="attention",
                    domain="float", kv_layout="dense")
    with pytest.raises(ValueError, match="no registered backend"):
        plan_matmul((32, 64, 128), "decode", op="attention",
                    domain="int8", kv_layout="paged")


def test_paged_attention_kernel_matches_gather_oracle():
    """The fused kernel's flash statistics against the gather oracle:
    the running max is bitwise identical; acc/l agree to f32 round-off
    (online vs single-pass summation order)."""
    from repro.kernels import paged_attention as pa
    s, kvh, rep, hd, ps, w = 3, 2, 3, 16, 8, 4
    key = jax.random.key(11)
    q = jax.random.normal(jax.random.fold_in(key, 0), (s, kvh, rep, hd),
                          jnp.float32)
    pool_shape = (1 + s * w, ps, kvh, hd)
    k_pages = jax.random.normal(jax.random.fold_in(key, 1), pool_shape,
                                jnp.float32)
    v_pages = jax.random.normal(jax.random.fold_in(key, 2), pool_shape,
                                jnp.float32)
    table = jnp.arange(1, 1 + s * w, dtype=jnp.int32).reshape(s, w)
    pos = jnp.asarray([29, 17, 32], jnp.int32)     # page-unaligned too
    kv = pa.PagedAttentionKV(k_pages, v_pages, table, pos)

    acc, m, l = pa.paged_attention(q, kv, interpret=True)
    acc_r, m_r, l_r = pa.paged_attention_ref(q, kv)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_r))
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_r),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- bench contract

def test_serve_paged_schema_gate():
    """schema.validate must reject a wallclock payload whose
    serve_paged section lost a contract key."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_schema", os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks", "schema.py"))
    schema = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(schema)

    root = os.path.join(os.path.dirname(__file__), "..")
    payload = json.load(open(os.path.join(root, "BENCH_wallclock.json")))
    assert schema.validate("wallclock", payload) == []

    broken = dict(payload)
    broken["serve_paged"] = {
        k: v for k, v in payload["serve_paged"].items()
        if k != "kv_bytes_paged_peak"}
    errs = schema.validate("wallclock", broken)
    assert any("kv_bytes_paged_peak" in e for e in errs)

    missing = dict(payload)
    del missing["serve_paged"]
    errs = schema.validate("wallclock", missing)
    assert any("serve_paged" in e for e in errs)

    broken = dict(payload)
    del broken["claim_paged_kv_bytes_2x"]
    errs = schema.validate("wallclock", broken)
    assert any("claim_paged_kv_bytes_2x" in e for e in errs)
