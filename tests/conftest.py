import numpy as np


class FakeMesh:
    """Duck-typed mesh (axis_names + devices) for rule resolution in
    tests without real devices — the contract dist.mesh.axis_sizes
    accepts."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)
