"""Data pipeline: determinism, shardability, learnable structure."""
import jax
import jax.numpy as jnp

from repro.data import DataConfig, lm_batch, class_batch, ClassTaskConfig, \
    entropy_floor
from repro.data.pipeline import _A, _B


CFG = DataConfig(vocab_size=512, seq_len=64, global_batch=8, seed=3)


def test_deterministic():
    a = lm_batch(CFG, jnp.asarray(5))
    b = lm_batch(CFG, jnp.asarray(5))
    assert jnp.array_equal(a["tokens"], b["tokens"])
    assert jnp.array_equal(a["labels"], b["labels"])
    c = lm_batch(CFG, jnp.asarray(6))
    assert not jnp.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    b = lm_batch(CFG, jnp.asarray(0))
    assert jnp.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_reconstructs_global_batch():
    """2 hosts each generating half == 1 host generating all."""
    full = lm_batch(CFG, jnp.asarray(2))
    h0 = lm_batch(CFG, jnp.asarray(2), host_index=0, num_hosts=2)
    h1 = lm_batch(CFG, jnp.asarray(2), host_index=1, num_hosts=2)
    stitched = jnp.concatenate([h0["tokens"], h1["tokens"]])
    assert jnp.array_equal(full["tokens"], stitched)


def test_chain_structure_is_learnable():
    """With noise/restart off, tokens follow t+1 = (a*t + b) mod v exactly."""
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=1,
                     restart_p=0.0, noise_p=0.0)
    b = lm_batch(cfg, jnp.asarray(0))
    t = b["tokens"]
    assert jnp.array_equal(t[:, 1:], (_A * t[:, :-1] + _B) % cfg.v)


def test_entropy_floor_bounds():
    h = entropy_floor(CFG)
    assert 0.0 < h < jnp.log(CFG.v)


def test_tokens_in_vocab_range():
    b = lm_batch(CFG, jnp.asarray(9))
    assert int(b["tokens"].min()) >= 0
    assert int(b["tokens"].max()) < CFG.vocab_size


def test_class_batch():
    cfg = ClassTaskConfig(num_classes=4, dim=16, snr=10.0)
    b = class_batch(cfg, jnp.asarray(0), batch=64)
    assert b["x"].shape == (64, 16)
    assert int(b["y"].max()) < 4
    # high SNR -> nearest-mean classifier near perfect
    from repro.data.pipeline import class_means
    mu = class_means(cfg)
    pred = jnp.argmin(
        jnp.linalg.norm(b["x"][:, None, :] - mu[None], axis=-1), axis=1)
    assert float((pred == b["y"]).mean()) > 0.95
