"""Continuous-batching scheduler (ISSUE 3 acceptance):

  * chunked-loop tokens are bitwise identical per request to BOTH PR 2
    drivers (on-device bucket loop and legacy per-step loop);
  * slot-state isolation: a slot reclaimed by compaction (admit-scatter
    over a freed slot) reproduces the solo run of the new request
    exactly, with no bleed-through from the previous occupant;
  * no starvation: every request of a bursty arrival trace completes,
    with its full token budget;
  * transfer accounting: the scheduler performs exactly one device->host
    transfer per chunk, and a saturated uniform workload runs exactly
    ceil(decode_steps / chunk) chunks;
  * the sharded slot pool (slot axis folded over 'data') emits the same
    tokens as the unsharded scheduler (slow subprocess test).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry
from repro.serve import (Request, Scheduler, ServeEngine, bursty_arrivals,
                         make_trace, poisson_arrivals, load_trace)

jax.config.update("jax_platform_name", "cpu")


def _setup(arch="internlm2-1.8b", dtype=jnp.float32):
    cfg = dataclasses.replace(configs.smoke(arch), dtype=dtype)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, specs):
    """specs: list of (uid, prompt_len, max_new[, eos_id, arrival_s])."""
    key = jax.random.key(1)
    out = []
    for spec in specs:
        uid, plen, max_new = spec[:3]
        eos = spec[3] if len(spec) > 3 else -1
        arr = spec[4] if len(spec) > 4 else 0.0
        prompt = jax.random.randint(jax.random.fold_in(key, uid),
                                    (plen,), 0, cfg.vocab_size)
        out.append(Request(uid=uid, prompt=prompt, max_new=max_new,
                           eos_id=eos, arrival_s=arr))
    return out


# ------------------------------------------------- token parity

def test_chunked_tokens_match_both_pr2_drivers():
    """Same requests through Scheduler, device bucket loop, and legacy
    step loop: per-request token VALUES must agree bitwise."""
    cfg, model, params = _setup()
    specs = [(i, 8, 3 + 2 * i) for i in range(4)]

    outs = []
    for engine in (
        Scheduler(model, params, capacity=64, slots=4, chunk=3),
        ServeEngine(model, params, capacity=64, max_batch=4,
                    on_device_loop=True),
        ServeEngine(model, params, capacity=64, max_batch=4,
                    on_device_loop=False),
    ):
        for r in _requests(cfg, specs):
            engine.submit(r)
        outs.append({r.uid: list(r.out_tokens) for r in engine.run()})
    assert outs[0] == outs[1] == outs[2]
    assert all(len(outs[0][uid]) == mn for uid, _, mn in specs)


def test_mixed_prompt_lengths_one_pool():
    """Slots at different sequence positions coexist: mixed prompt
    lengths decode concurrently in one pool (the bucket driver would
    split them into separate batches)."""
    cfg, model, params = _setup()
    specs = [(0, 4, 5), (1, 8, 5), (2, 16, 5), (3, 6, 5)]
    sch = Scheduler(model, params, capacity=64, slots=4, chunk=4)
    for r in _requests(cfg, specs):
        sch.submit(r)
    got = {r.uid: list(r.out_tokens) for r in sch.run()}

    ref = {}
    for spec in specs:
        eng = ServeEngine(model, params, capacity=64, max_batch=1)
        for r in _requests(cfg, [spec]):
            eng.submit(r)
        ref.update({r.uid: list(r.out_tokens) for r in eng.run()})
    assert got == ref


# ------------------------------------------------- compaction / isolation

def test_slot_reuse_isolation_after_compaction():
    """More requests than slots: freed slots are reclaimed by the admit
    scatter. Every request must match its solo (batch-1) reference run —
    state bleed-through from a previous occupant would diverge here."""
    cfg, model, params = _setup()
    specs = [(i, 8 if i % 2 else 6, 3 + (i % 4)) for i in range(8)]
    sch = Scheduler(model, params, capacity=64, slots=2, chunk=3)
    for r in _requests(cfg, specs):
        sch.submit(r)
    got = {r.uid: list(r.out_tokens) for r in sch.run()}
    assert sorted(got) == [s[0] for s in specs]

    for spec in specs:
        eng = ServeEngine(model, params, capacity=64, max_batch=1)
        for r in _requests(cfg, [spec]):
            eng.submit(r)
        solo = eng.run()[0]
        assert got[solo.uid] == list(solo.out_tokens), \
            f"slot reuse corrupted request {solo.uid}"


# ------------------------------------------------- starvation / bursts

def test_no_starvation_under_bursty_trace():
    """Two bursts against a 2-slot pool: every submitted request
    completes with its full budget (FIFO admission; EOS disabled)."""
    cfg, model, params = _setup()
    arrivals = bursty_arrivals(10, bursts=2, gap_s=0.05, spread_s=0.01,
                               seed=3)
    trace = make_trace(arrivals, prompt_lens=[6, 8], max_news=[2, 5, 3])
    specs = [(i, rec["prompt_len"], rec["max_new"], -1, rec["arrival_s"])
             for i, rec in enumerate(trace)]
    sch = Scheduler(model, params, capacity=64, slots=2, chunk=3)
    for r in _requests(cfg, specs):
        sch.submit(r)
    done = sch.run()
    assert sorted(r.uid for r in done) == list(range(10))
    for r in done:
        assert len(r.out_tokens) == r.max_new
        assert r.done and r.latency_s >= 0.0


def test_trace_generators():
    arr = poisson_arrivals(5, rate_per_s=100.0, seed=1)
    assert len(arr) == 5 and arr == sorted(arr) and arr[0] > 0
    assert poisson_arrivals(3, 0.0) == [0.0, 0.0, 0.0]
    arr = bursty_arrivals(6, bursts=2, gap_s=1.0, spread_s=0.0)
    assert arr[:3] == [0.0] * 3 and arr[3:] == [1.0] * 3
    trace = make_trace(arr, [8, 16], [4])
    assert trace[0]["prompt_len"] == 8 and trace[1]["prompt_len"] == 16
    assert all(t["max_new"] == 4 for t in trace)


def test_load_trace_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "trace.json")
    with open(path, "w") as f:
        json.dump([{"arrival_s": 0.5, "prompt_len": 8, "max_new": 3},
                   {"arrival_s": 0.7, "prompt_len": 4, "max_new": 2,
                    "priority": 1, "deadline_s": 0.25}], f)
    trace = load_trace(path)
    assert trace[0] == {"arrival_s": 0.5, "prompt_len": 8, "max_new": 3,
                        "eos_id": -1, "priority": 0, "deadline_s": None}
    assert trace[1]["priority"] == 1 and trace[1]["deadline_s"] == 0.25


# ------------------------------------------------- transfer accounting

def test_one_transfer_per_chunk_and_ceil_accounting():
    """Uniform saturated pool: decode_steps == max_new - 1 and the
    scheduler runs exactly ceil(steps / chunk) chunks, one host
    transfer each, at 100% slot occupancy."""
    cfg, model, params = _setup()
    max_new, chunk = 10, 4
    specs = [(i, 8, max_new) for i in range(4)]
    sch = Scheduler(model, params, capacity=64, slots=4, chunk=chunk)
    for r in _requests(cfg, specs):
        sch.submit(r)
    sch.run()
    steps = max_new - 1                      # tok0 comes from prefill
    assert sch.decode_steps == steps
    assert sch.chunks_run == -(-steps // chunk)
    assert sch.host_transfers == sch.chunks_run
    assert sch.slot_occupancy == 1.0


def test_eos_stops_slot_and_frees_it():
    cfg, model, params = _setup()
    prompt = jnp.zeros((4,), jnp.int32)
    from repro.serve import make_prefill_step
    pre = make_prefill_step(model, 32)
    tok, _ = pre(params, {"tokens": prompt[None]})
    eos = int(tok[0])                        # greedy's first token
    sch = Scheduler(model, params, capacity=32, slots=1, chunk=4)
    sch.submit(Request(uid=0, prompt=prompt, max_new=8, eos_id=eos))
    sch.submit(Request(uid=1, prompt=jnp.ones((4,), jnp.int32), max_new=3))
    done = sch.run()
    by_uid = {r.uid: r for r in done}
    assert len(by_uid[0].out_tokens) == 1    # tok0 == eos: stops at once
    assert len(by_uid[1].out_tokens) == 3    # slot was freed and reused


def test_idle_pool_emits_tok0_with_zero_steps():
    """max_new=1 requests never enter the decode loop: the chunk
    prologue emits the prefill token and the slot retires with zero
    decode steps (still exactly one transfer for the chunk)."""
    cfg, model, params = _setup()
    sch = Scheduler(model, params, capacity=32, slots=2, chunk=4)
    for r in _requests(cfg, [(0, 6, 1), (1, 6, 1)]):
        sch.submit(r)
    done = sch.run()
    assert all(len(r.out_tokens) == 1 for r in done)
    assert sch.decode_steps == 0
    assert sch.chunks_run == 1 == sch.host_transfers


# ------------------------------------------------- sharded slot pool

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
from repro import configs
from repro.dist import mesh as mesh_lib, sharding as shd
from repro.models import registry
from repro.serve import Request, Scheduler

cfg = dataclasses.replace(configs.smoke("internlm2-1.8b"),
                          dtype=jnp.float32)
model = registry.build(cfg)
params = model.init(jax.random.key(0))
key = jax.random.key(1)

def reqs():
    return [Request(uid=i,
                    prompt=jax.random.randint(jax.random.fold_in(key, i),
                                              (8,), 0, cfg.vocab_size),
                    max_new=3 + i)
            for i in range(4)]

def run(spmd_axes, rules=None, mesh=None):
    shd.set_activation_context(rules, mesh)
    sch = Scheduler(model, params, capacity=32, slots=4, chunk=3,
                    spmd_axes=spmd_axes)
    for r in reqs():
        sch.submit(r)
    return {r.uid: list(r.out_tokens) for r in sch.run()}

ref = run(None)

mesh = mesh_lib.make_mesh(mesh_lib.MeshSpec((2, 4), ("data", "model")))
rules = shd.rules_for(cfg, "serve")
got = run(shd.slot_spmd_axes(rules, mesh, 4), rules, mesh)

print(json.dumps({"identical": got == {str(k): v for k, v in ref.items()}
                               or got == ref,
                  "devices": jax.device_count(),
                  "spmd_axes": str(shd.slot_spmd_axes(rules, mesh, 4))}))
"""


@pytest.mark.slow
def test_sharded_slot_pool_matches_unsharded():
    """The slot axis sharded over 'data' (vmap spmd_axis_name through
    dist.sharding.slot_spmd_axes) must not change a single token."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["spmd_axes"] == "data"
    assert out["identical"]


# ------------------------------------------------- bench contract

def test_serve_continuous_schema_gate():
    """schema.validate must reject a wallclock payload whose
    serve_continuous section lost a contract key."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_schema", os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks", "schema.py"))
    schema = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(schema)

    root = os.path.join(os.path.dirname(__file__), "..")
    payload = json.load(open(os.path.join(root, "BENCH_wallclock.json")))
    assert schema.validate("wallclock", payload) == []

    broken = dict(payload)
    broken["serve_continuous"] = {
        k: v for k, v in payload["serve_continuous"].items()
        if k != "continuous"}
    errs = schema.validate("wallclock", broken)
    assert errs and "serve_continuous" in errs[0]

    broken = dict(payload)
    broken["serve_continuous"] = dict(
        payload["serve_continuous"],
        continuous={k: v for k, v
                    in payload["serve_continuous"]["continuous"].items()
                    if k != "slot_occupancy"})
    errs = schema.validate("wallclock", broken)
    assert any("slot_occupancy" in e for e in errs)

    missing = dict(payload)
    del missing["serve_continuous"]
    errs = schema.validate("wallclock", missing)
    assert any("serve_continuous" in e for e in errs)
