"""Serving front-end (ISSUE 9 acceptance):

  * token parity: the front-end drives schedulers through the public
    pump API only, so per-request tokens are bitwise identical to
    driving the scheduler directly with the same records;
  * bounded queue + explicit backpressure: pending never exceeds
    queue_limit, every submit is accounted (completed or rejected with
    a reason), nothing is silently dropped;
  * SLO admission: priority preempts FIFO order at admission, doomed
    deadlines are shed (passed / unmeetable), FIFO never sheds;
  * two-model isolation: interleaved traffic through one server keeps
    per-model tokens bitwise equal to each model's solo direct run;
  * streaming transfer accounting: host_transfers == chunks survives
    the front-end (the stream drains the chunk payload, no extra sync);
  * determinism: one trace replayed twice under a virtual clock gives
    identical admission logs and tokens;
  * trace contract: validate/save/load round-trip, TraceError on
    malformed records; latency_stats p999 + queue-wait/service split;
  * bench contract: schema.validate rejects a wallclock payload whose
    serve_frontend section lost a key, a claim, or its accounting.
"""
import json
import os

import jax
import pytest

from repro.frontend import (FIFOAdmission, FrontendServer, ModelRegistry,
                            ModelSpec, SLOAdmission, VirtualClock,
                            deadline_at, replay, replay_direct,
                            trace_requests)
from repro.serve import (Request, TraceError, latency_stats, load_trace,
                         make_trace, save_trace, validate_trace)

jax.config.update("jax_platform_name", "cpu")

ARCH_A, ARCH_B = "internlm2-1.8b", "qwen3-14b"


@pytest.fixture(scope="module")
def registry():
    """Two smoke-model pools, built lazily on first targeted request;
    shared across tests (all front-end/replay counters are per-epoch
    deltas, so a warm registry is safe to reuse)."""
    reg = ModelRegistry()
    for arch in (ARCH_A, ARCH_B):
        reg.register(ModelSpec(name=arch, arch=arch, smoke=True,
                               kind="paged", capacity=64, slots=2,
                               chunk=4, page_size=16))
    return reg


def _virtual_server(reg, admission=None, queue_limit=64):
    clock = VirtualClock()
    server = FrontendServer(reg, admission, queue_limit=queue_limit,
                            clock=clock)
    return server, clock


def _replay(server, clock, records, **kw):
    return replay(server, records, sleep=clock.advance,
                  tick=lambda: clock.advance(0.01), **kw)


# ------------------------------------------------- registry

def test_registry_lazy_instantiation_and_capacity_report():
    reg = ModelRegistry()
    reg.register(ModelSpec(name="m", arch=ARCH_A))
    assert "m" in reg and ARCH_A not in reg
    assert not reg.is_instantiated("m")
    report = reg.capacity_report()
    assert report["m"]["instantiated"] is False
    assert "kv_bytes_pool" not in report["m"]     # no pool was built
    with pytest.raises(ValueError, match="already registered"):
        reg.register(ModelSpec(name="m", arch=ARCH_A))
    with pytest.raises(ValueError, match="kind"):
        reg.register(ModelSpec(name="x", arch=ARCH_A, kind="bucket"))
    with pytest.raises(KeyError, match="unknown model"):
        reg.spec("ghost")


# ------------------------------------------------- token parity

def test_tokens_bitwise_identical_to_direct_scheduler(registry):
    trace = make_trace([0.0] * 6, [6, 8], [5, 7])
    records = trace_requests(trace, registry, [ARCH_A], seed=0)
    server, clock = _virtual_server(registry)
    rep = _replay(server, clock, records, collect_tokens=True)
    assert rep["completed"] == 6 and rep["rejected"] == 0
    fe_tokens = [rep["out_tokens"][u] for u in sorted(rep["out_tokens"])]
    _, by_uid = replay_direct(registry, records)
    dt_tokens = [by_uid[r["uid"]] for r in records]
    assert fe_tokens == dt_tokens
    for toks, rec in zip(fe_tokens, records):
        assert len(toks) == rec["max_new"]


def test_two_model_isolation_interleaved(registry):
    """Interleaved traffic over both pools through ONE server: each
    model's tokens must equal its solo direct run — no cross-model
    state bleed through the shared front-end."""
    trace = make_trace([0.0] * 8, [6, 8], [4, 6])
    records = trace_requests(trace, registry, [ARCH_A, ARCH_B], seed=3)
    assert {r["model"] for r in records} == {ARCH_A, ARCH_B}
    server, clock = _virtual_server(registry)
    rep = _replay(server, clock, records, collect_tokens=True)
    assert rep["completed"] == 8
    _, by_uid = replay_direct(registry, records)
    fe_tokens = [rep["out_tokens"][u] for u in sorted(rep["out_tokens"])]
    for toks, rec in zip(fe_tokens, records):
        assert toks == by_uid[rec["uid"]], rec["model"]


def test_streaming_transfer_accounting_and_hook(registry):
    """host_transfers == chunks across the replay, and the on_tokens
    delivery hook sees every token exactly once, in order."""
    trace = make_trace([0.0] * 3, [6], [6])
    records = trace_requests(trace, registry, [ARCH_A], seed=1)
    server, clock = _virtual_server(registry)
    got = {}
    server.begin()
    streams = [server.submit(r["model"], r["prompt"],
                             max_new=r["max_new"], eos_id=r["eos_id"],
                             on_tokens=lambda s, new:
                             got.setdefault(s.uid, []).extend(new))
               for r in records]
    t0, c0 = server.host_transfers, server.chunks
    server.drain()
    assert server.host_transfers - t0 == server.chunks - c0 > 0
    for s in streams:
        assert s.status == "done" and s.finished
        assert got[s.uid] == s.tokens == list(s.req.out_tokens)
        assert s.ttft_s is not None and s.ttft_s >= 0.0


# ------------------------------------------------- backpressure

def test_bounded_queue_rejects_with_reason(registry):
    trace = make_trace([0.0] * 6, [6], [4])
    records = trace_requests(trace, registry, [ARCH_A], seed=2)
    server, clock = _virtual_server(registry, queue_limit=2)
    rep = _replay(server, clock, records)
    assert rep["max_pending_seen"] <= 2
    assert rep["submitted"] == 6
    assert rep["submitted"] == rep["completed"] + rep["rejected"]
    assert server.in_flight == 0
    assert rep["rejects_by_reason"].get("queue-full", 0) == rep["rejected"]
    assert rep["rejected"] > 0


def test_submit_rejects_unknown_model_and_over_capacity(registry):
    server, _ = _virtual_server(registry)
    s = server.submit("ghost", [1, 2, 3])
    assert s.status == "rejected" and s.reason == "unknown-model"
    s = server.submit(ARCH_A, list(range(60)), max_new=10)  # 70 > 64
    assert s.status == "rejected" and s.reason == "over-capacity"
    assert not s.accepted and s.finished
    assert server.rejects_by_reason == {"unknown-model": 1,
                                        "over-capacity": 1}
    assert server.submitted == len(server.rejected) == 2
    with pytest.raises(ValueError, match="queue_limit"):
        FrontendServer(registry, queue_limit=0)


# ------------------------------------------------- SLO admission

def test_priority_preempts_fifo_admission_order(registry):
    """Four same-arrival requests, priorities [1, 1, 0, 0], two slots:
    the SLO policy admits the urgent class first (uids 2, 3); FIFO
    admits submission order (uids 0, 1)."""
    def first_admits(policy):
        server, clock = _virtual_server(registry, admission=policy)
        server.begin()
        for i, pri in enumerate([1, 1, 0, 0]):
            server.submit(ARCH_A, [1 + i] * 6, max_new=3, priority=pri)
        server.poll()
        admits = [e[1] for e in server.admission_log
                  if e[0] == "admit"]
        server.drain()
        return admits[:2]

    assert first_admits(SLOAdmission()) == [2, 3]
    assert first_admits(FIFOAdmission()) == [0, 1]


def test_slo_sheds_passed_and_unmeetable_deadlines(registry):
    server, clock = _virtual_server(
        registry, admission=SLOAdmission(service_floor_s=1.0))
    server.begin()
    doomed = server.submit(ARCH_A, [1] * 6, max_new=3, deadline_s=0.05)
    tight = server.submit(ARCH_A, [2] * 6, max_new=3, deadline_s=0.5)
    free = server.submit(ARCH_A, [3] * 6, max_new=3)
    clock.advance(0.1)   # past doomed's deadline; tight needs 1.0s floor
    server.drain()
    assert doomed.status == "shed" and doomed.reason == "deadline-passed"
    assert tight.status == "shed" and tight.reason == "deadline-unmeetable"
    assert free.status == "done" and len(free.tokens) == 3
    assert server.rejects_by_reason == {"deadline-passed": 1,
                                        "deadline-unmeetable": 1}
    assert server.submitted == len(server.completed) + len(server.rejected)


def test_fifo_never_sheds_and_deadline_at():
    fifo = FIFOAdmission()
    late = Request(uid=0, prompt=[1], max_new=1, arrival_s=0.0,
                   deadline_s=0.01)
    assert fifo.shed_reason(late, now=99.0) is None
    assert deadline_at(late) == 0.01
    assert deadline_at(Request(uid=1, prompt=[1], max_new=1,
                               arrival_s=2.0)) == float("inf")
    slo = SLOAdmission()
    assert slo.shed_reason(late, now=0.005) is None
    assert slo.shed_reason(late, now=0.01) == "deadline-passed"


def test_admission_log_deterministic_across_replays(registry):
    """Same records, two fresh servers on one virtual timeline recipe:
    identical decision logs, tokens, and shed accounting."""
    trace = make_trace([round(0.01 * i, 6) for i in range(6)],
                       [6, 8], [4, 6], priorities=[0, 1],
                       deadlines=[0.08, None])
    records = trace_requests(trace, registry, [ARCH_A], seed=5)

    def run():
        server, clock = _virtual_server(
            registry, admission=SLOAdmission(service_floor_s=0.02))
        rep = _replay(server, clock, records, collect_tokens=True)
        return server.admission_log, rep

    log1, rep1 = run()
    log2, rep2 = run()
    assert log1 == log2
    assert rep1["out_tokens"] == rep2["out_tokens"]
    assert rep1["shed"] == rep2["shed"]
    assert rep1["deadline_met"] == rep2["deadline_met"]


# ------------------------------------------------- trace contract

def test_trace_roundtrip_and_canonicalization(tmp_path):
    trace = make_trace([0.0, 0.5], [8], [4], priorities=[0, 1],
                       deadlines=[None, 0.25])
    path = os.path.join(tmp_path, "t.json")
    save_trace(path, trace)
    assert load_trace(path) == validate_trace(trace) == trace
    # defaults are filled on the way in
    got = validate_trace([{"arrival_s": 0, "prompt_len": 4, "max_new": 2}])
    assert got == [{"arrival_s": 0.0, "prompt_len": 4, "max_new": 2,
                    "eos_id": -1, "priority": 0, "deadline_s": None}]


@pytest.mark.parametrize("bad,msg", [
    ({"not": "a list"}, "expected a JSON list"),
    ([[1, 2]], "expected an object"),
    ([{"arrival_s": 0.0}], "missing required keys"),
    ([{"arrival_s": -1, "prompt_len": 4, "max_new": 2}],
     "negative arrival_s"),
    ([{"arrival_s": 1.0, "prompt_len": 4, "max_new": 2},
      {"arrival_s": 0.5, "prompt_len": 4, "max_new": 2}],
     "sorted by arrival"),
    ([{"arrival_s": 0, "prompt_len": 0, "max_new": 2}], "prompt_len"),
    ([{"arrival_s": 0, "prompt_len": 4, "max_new": 0}], "max_new"),
    ([{"arrival_s": 0, "prompt_len": 4, "max_new": 2,
       "deadline_s": -0.5}], "deadline_s must be positive"),
    ([{"arrival_s": "soon", "prompt_len": 4, "max_new": 2}],
     "non-numeric"),
])
def test_trace_validation_errors(bad, msg):
    with pytest.raises(TraceError, match=msg):
        validate_trace(bad)


def test_load_trace_malformed_json(tmp_path):
    path = os.path.join(tmp_path, "bad.json")
    with open(path, "w") as f:
        f.write("{nope")
    with pytest.raises(TraceError, match="unparseable JSON"):
        load_trace(path)


# ------------------------------------------------- latency breakdown

def test_latency_stats_p999_and_queue_service_split():
    reqs = [Request(uid=0, prompt=[1], max_new=1, arrival_s=0.0,
                    latency_s=1.0, admit_s=0.3),
            Request(uid=1, prompt=[1], max_new=1, arrival_s=1.0,
                    latency_s=0.5, admit_s=0.8)]   # admit before arrival
    st = latency_stats(reqs)
    assert st["mean_s"] == 0.75
    # uid 0 waited 0.3 then decoded 0.7; uid 1's wait clamps to 0
    assert st["queue_wait_mean_s"] == 0.15
    assert st["service_mean_s"] == 0.6
    assert st["p50_s"] <= st["p99_s"] <= st["p999_s"] <= 1.0
    zero = latency_stats([])
    assert zero["p999_s"] == 0.0 and zero["queue_wait_mean_s"] == 0.0
    many = [Request(uid=i, prompt=[1], max_new=1,
                    latency_s=float(i) / 1000.0, admit_s=0.0)
            for i in range(1001)]
    st = latency_stats(many)
    assert st["p99_s"] < st["p999_s"] < 1.0   # interpolated, not max


def test_virtual_clock():
    clock = VirtualClock()
    assert clock() == 0.0
    clock.advance(0.5)
    clock.sleep(0.25)
    clock.advance(-1.0)        # clamps: time never goes backwards
    assert clock() == 0.75


# ------------------------------------------------- bench contract

def test_serve_frontend_schema_gate():
    """schema.validate must reject a wallclock payload whose
    serve_frontend section lost a contract key, a claim, its
    accounting identity, or its FIFO-ungated declaration."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_schema", os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks", "schema.py"))
    schema = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(schema)

    root = os.path.join(os.path.dirname(__file__), "..")
    payload = json.load(open(os.path.join(root, "BENCH_wallclock.json")))
    assert schema.validate("wallclock", payload) == []

    broken = dict(payload)
    broken["serve_frontend"] = {
        k: v for k, v in payload["serve_frontend"].items()
        if k != "tok_per_s_goodput_slo"}
    errs = schema.validate("wallclock", broken)
    assert any("tok_per_s_goodput_slo" in e for e in errs)

    missing = dict(payload)
    del missing["serve_frontend"]
    errs = schema.validate("wallclock", missing)
    assert any("serve_frontend" in e for e in errs)

    broken = dict(payload)
    del broken["claim_frontend_tokens_identical"]
    errs = schema.validate("wallclock", broken)
    assert any("claim_frontend_tokens_identical" in e for e in errs)

    # the accounting identity is structural, not just key presence
    broken = json.loads(json.dumps(payload))
    broken["serve_frontend"]["overload"]["rejected"] += 1
    errs = schema.validate("wallclock", broken)
    assert any("silently dropped" in e for e in errs)

    # the adversarial FIFO baseline must STAY out of the perf gate
    broken = json.loads(json.dumps(payload))
    broken["serve_frontend"]["ungated_metrics"] = []
    errs = schema.validate("wallclock", broken)
    assert any("tok_per_s_goodput_fifo" in e for e in errs)
