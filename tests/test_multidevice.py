"""Multi-device SPMD correctness on fake CPU devices (subprocess — the
device count must be set before jax initializes, which pytest's process
has already done)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro import configs
from repro.data import DataConfig, batch_for
from repro.dist import mesh as mesh_lib, sharding as shd
from repro.models import registry
from repro.optim import adamw
from repro.train.step import init_state, make_train_step

cfg = configs.smoke("internlm2-1.8b")
model = registry.build(cfg)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
batch = batch_for(cfg, dc, jnp.asarray(0))
opt = adamw(1e-3)

# 1-device reference
state = init_state(model, opt, jax.random.key(0))
step1 = jax.jit(make_train_step(model, opt))
ref_state, ref_m = step1(state, batch)

# 8-device (2 data x 4 model) sharded
mesh = mesh_lib.make_mesh(mesh_lib.MeshSpec((2, 4), ("data", "model")))
rules = shd.rules_for(cfg, "train")
shd.set_activation_context(rules, mesh)
state = init_state(model, opt, jax.random.key(0))
stepN = jax.jit(make_train_step(model, opt, rules=rules, mesh=mesh))
got_state, got_m = stepN(state, batch)

diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
           for a, b in zip(jax.tree.leaves(ref_state.params),
                           jax.tree.leaves(got_state.params)))
# HLO must contain collectives when sharded
txt = stepN.lower(state, batch).compile().as_text()
print(json.dumps({
    "loss_ref": float(ref_m["loss"]), "loss_got": float(got_m["loss"]),
    "max_param_diff": diff,
    "has_collectives": ("all-reduce" in txt) or ("all-gather" in txt),
    "devices": jax.device_count(),
}))
"""


@pytest.mark.slow
def test_sharded_step_matches_single_device(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert abs(out["loss_ref"] - out["loss_got"]) < 1e-3
    assert out["max_param_diff"] < 5e-2          # bf16 reduction-order noise
    assert out["has_collectives"]
