"""int8 KV cache (beyond-paper: the paper's narrow-storage + restore
mechanism applied to the decode-time activations)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry
from repro.models.attention import quantize_kv


def test_quantize_kv_roundtrip_error():
    x = jax.random.normal(jax.random.key(0), (2, 8, 4, 16))
    q, s = quantize_kv(x)
    deq = q.astype(jnp.float32) * s[..., None]
    # max error bounded by half a code step per (b, pos, head)
    assert float(jnp.max(jnp.abs(deq - x) / s[..., None])) <= 0.5 + 1e-4


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "glm4-9b",
                                  "mixtral-8x7b"])
def test_int8_kv_decode_close_to_bf16(arch):
    cfg = dataclasses.replace(configs.smoke(arch), dtype=jnp.float32)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model, model8 = registry.build(cfg), registry.build(cfg8)
    params = model.init(jax.random.key(4))
    toks = jax.random.randint(jax.random.key(5), (2, 12), 0, cfg.vocab_size)

    lg, st = model.prefill(params, {"tokens": toks}, capacity=24)
    lg8, st8 = model8.prefill(params, {"tokens": toks}, capacity=24)
    assert st8["k"].dtype == jnp.int8
    assert jnp.allclose(lg, lg8, atol=1e-4)     # prefill logits identical

    tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        lg, st = model.decode(params, tok, st)
        lg8, st8 = model8.decode(params, tok, st8)
        # int8 cache introduces bounded quantization noise (random-weight
        # models have near-uniform attention, the worst case for it)
        denom = jnp.maximum(jnp.max(jnp.abs(lg)), 1e-6)
        assert float(jnp.max(jnp.abs(lg - lg8)) / denom) < 0.25
        assert float(jnp.mean(jnp.abs(lg - lg8)) / denom) < 0.05
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)


def test_int8_kv_cache_defs_have_scales():
    cfg = dataclasses.replace(configs.get("qwen3-14b"),
                              kv_cache_dtype="int8")
    model = registry.build(cfg)
    defs = model.cache_defs(4, 128)
    assert defs["k"].dtype == jnp.int8
    assert defs["k_scale"].shape == (cfg.num_layers, 4, 128,
                                     cfg.num_kv_heads)


def test_init_cache_allocates_int8_scale_buffers():
    """ISSUE 5 satellite: attn.init_cache used to allocate no
    k_scale/v_scale while registry gates its int8 read path on them."""
    from repro.models.attention import init_cache
    c = init_cache(2, 16, 4, 8, kv_cache_dtype="int8")
    assert c.k.dtype == jnp.int8 and c.v.dtype == jnp.int8
    assert c.k_scale is not None and c.v_scale is not None
    assert c.k_scale.shape == (2, 16, 4) and c.k_scale.dtype == jnp.float32
    # default float path unchanged
    c = init_cache(2, 16, 4, 8)
    assert c.k_scale is None and c.v_scale is None


def test_int8_kv_scheduler_parity_mixed_lengths():
    """Mixed-length int8-KV pools: the continuous Scheduler (dense slot
    pool, scale buffers allocated up front on the slot axis) and the
    PagedScheduler (scale pages alongside the KV pages) must both be
    bitwise token-identical to the batch-1 bucket driver."""
    from repro.serve import PagedScheduler, Request, Scheduler, ServeEngine
    cfg = dataclasses.replace(configs.smoke("internlm2-1.8b"),
                              dtype=jnp.float32, kv_cache_dtype="int8")
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    key = jax.random.key(1)

    def reqs():
        return [Request(uid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab_size),
            max_new=mn)
            for i, (plen, mn) in enumerate([(6, 4), (10, 6), (8, 3),
                                            (6, 5)])]

    def run(eng):
        for r in reqs():
            eng.submit(r)
        return {r.uid: list(r.out_tokens) for r in eng.run()}

    ref = run(ServeEngine(model, params, capacity=32, max_batch=1))
    dense = Scheduler(model, params, capacity=32, slots=2, chunk=3)
    # the slot pool carries int8 codes + f32 scale lanes from t=0
    assert dense.pool["k"].dtype == jnp.int8
    assert dense.pool["k_scale"].shape[0] == 2      # slot axis
    assert run(dense) == ref
    paged = PagedScheduler(model, params, capacity=32, slots=2, chunk=3,
                           page_size=4)
    assert paged.pool.k_pages.dtype == jnp.int8
    assert paged.pool.k_scale_pages is not None     # scale pages up front
    assert run(paged) == ref
