"""End-to-end system test: train a smoke model on the synthetic chain task
through the Trainer (checkpointing on), then serve it packed-ternary —
the full paper pipeline (train -> quantize-to-trits -> CIM-serve)."""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.cim_linear import CIMConfig, ternarize_params
from repro.data import DataConfig, lm_batch
from repro.models import registry
from repro.optim import adamw
from repro.serve import Request, ServeEngine
from repro.train import Trainer, TrainerConfig


def test_train_then_cim_serve(tmp_path):
    cfg = configs.smoke("internlm2-1.8b")
    model = registry.build(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      seed=11)
    tc = TrainerConfig(total_steps=30, ckpt_dir=str(tmp_path),
                       ckpt_interval=10, seed=11)
    tr = Trainer(model, adamw(3e-3), data, tc)
    state = tr.run()

    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] - 0.3, losses[::6]

    # quantize the trained weights to the paper's 5-trit format and serve
    cim = CIMConfig(mode="ternary", packing="base3")
    packed = ternarize_params(state.params, cim)
    eng = ServeEngine(model, packed, capacity=96, max_batch=4, cim=cim)
    prompts = lm_batch(data, jnp.asarray(999))["tokens"][:4, :32]
    for i in range(4):
        eng.submit(Request(uid=i, prompt=prompts[i], max_new=4))
    done = eng.run()
    assert len(done) == 4
    assert all(len(r.out_tokens) == 4 for r in done)
