"""Elastic restart: a checkpoint written while training on one mesh
restarts on a DIFFERENT mesh (the scale-up/down path) with bitwise-
identical results — subprocess with 8 fake devices."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, shutil, tempfile
import jax, jax.numpy as jnp
from repro import checkpoint as ck
from repro import configs
from repro.data import DataConfig, batch_for
from repro.dist import mesh as mesh_lib, sharding as shd
from repro.models import registry
from repro.optim import adamw
from repro.train.step import init_state, make_train_step, TrainState

cfg = configs.smoke("internlm2-1.8b")
model = registry.build(cfg)
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
opt = adamw(1e-3)
ckdir = tempfile.mkdtemp()

def run_steps(state, step_fn, a, b):
    for i in range(a, b):
        state, m = step_fn(state, batch_for(cfg, dc, jnp.asarray(i)))
    return state

# ---- reference: 8 steps on mesh A (2 data x 4 model)
mesh_a = mesh_lib.make_mesh(mesh_lib.MeshSpec((2, 4), ("data", "model")))
rules_a = shd.rules_for(cfg, "train")
shd.set_activation_context(rules_a, mesh_a)
step_a = jax.jit(make_train_step(model, opt, rules=rules_a, mesh=mesh_a))
state = init_state(model, opt, jax.random.key(0))
ref = run_steps(state, step_a, 0, 8)

# ---- elastic: 4 steps on mesh A, checkpoint, restore onto mesh B (8 data)
state = init_state(model, opt, jax.random.key(0))
state = run_steps(state, step_a, 0, 4)
ck.save(ckdir, 4, state)

mesh_b = mesh_lib.make_mesh(mesh_lib.MeshSpec((8, 1), ("data", "model")))
rules_b = shd.rules_for(cfg, "train")
shd.set_activation_context(rules_b, mesh_b)
step_b = jax.jit(make_train_step(model, opt, rules=rules_b, mesh=mesh_b))
fresh = init_state(model, opt, jax.random.key(0))
shardings = jax.tree.map(
    lambda x: jax.sharding.NamedSharding(mesh_b, jax.sharding.PartitionSpec()),
    fresh)
tree, _ = ck.restore(ckdir, target=fresh, shardings=shardings)
state_b = TrainState(*tree) if not isinstance(tree, TrainState) else tree
got = run_steps(state_b, step_b, 4, 8)

diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
           for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(got.params)))
shutil.rmtree(ckdir, ignore_errors=True)
print(json.dumps({"max_diff": diff, "step": int(got.step)}))
"""


@pytest.mark.slow
def test_elastic_mesh_change():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["step"] == 8
    # bf16 params, different reduction orders across meshes -> tiny noise
    assert out["max_diff"] < 5e-2, out