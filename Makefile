# Tier-1 verification. The multi-device tests (test_multidevice,
# test_elastic) spawn subprocesses that force 8 fake CPU devices via
# XLA_FLAGS before jax initializes; exporting the flag here too keeps
# the top-level process consistent on CPU-only hosts and makes the run
# reproducible regardless of the caller's environment.
XLA_DEVICES ?= 8

.PHONY: verify test test-fast ci analyze dryrun-smoke bench bench-compare

verify: test

test:
	XLA_DEVICES=$(XLA_DEVICES) scripts/verify.sh

# skip the multi-minute subprocess tests (inner loop) — routed through
# scripts/verify.sh so it runs under the SAME fake-device XLA_FLAGS and
# path setup as the full suite (a bare `pytest` invocation here used to
# diverge from what CI enforces)
test-fast:
	XLA_DEVICES=$(XLA_DEVICES) scripts/verify.sh -m "not slow"

# the full CI pipeline locally: analysis gate + tier-1 suite + the
# bench schema gate + the perf-regression gate — exactly what
# .github/workflows/ci.yml runs (as separate jobs)
ci: analyze test bench bench-compare

# static contract checker + sanitizer (src/repro/analysis/README.md):
# capability lattice vs the kernels README matrix, pallas block/index
# maps, the sharding-contract prover, the jaxpr dataflow audit, the
# serve transfer/retrace contract, and the AST lint — exits nonzero on
# any finding, and writes the machine-readable findings document (the
# CI artifact). Same offline fake-device env as the tests.
analyze:
	mkdir -p experiments/analysis
	XLA_FLAGS="--xla_force_host_platform_device_count=$(XLA_DEVICES)" \
	    PYTHONPATH=src python -m repro.analysis \
	    --out experiments/analysis/findings.json

# perf-trajectory benchmarks (kernel_bench + wallclock, reduced sweeps)
# under the same 8-fake-device env as the tests; fails if the tracked
# BENCH_wallclock.json baseline or the regenerated (gitignored)
# experiments/benchmarks/*.json copies are missing or schema-invalid
# (benchmarks/schema.py). Only `python -m benchmarks.wallclock`
# rewrites the tracked baseline.
bench:
	XLA_FLAGS="--xla_force_host_platform_device_count=$(XLA_DEVICES)" \
	    PYTHONPATH=src python -m benchmarks.run --fast

# perf-regression gate: diff the regenerated (gitignored)
# experiments/benchmarks/wallclock.json against the tracked
# BENCH_wallclock.json baseline — every tok_per_s_* / step_time_s*
# metric, semantic shape-cell keys, fail on >15% regression
# (benchmarks/compare.py). Run `make bench` first.
bench-compare:
	PYTHONPATH=src python -m benchmarks.compare

# one dry-run cell as a launcher smoke check (compiles a 256-chip train
# step against ShapeDtypeStructs; no allocation)
dryrun-smoke:
	PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
	    --shape train_4k
