# Tier-1 verification. The multi-device tests (test_multidevice,
# test_elastic) spawn subprocesses that force 8 fake CPU devices via
# XLA_FLAGS before jax initializes; exporting the flag here too keeps
# the top-level process consistent on CPU-only hosts and makes the run
# reproducible regardless of the caller's environment.
XLA_DEVICES ?= 8

.PHONY: verify test test-fast dryrun-smoke

verify: test

test:
	XLA_DEVICES=$(XLA_DEVICES) scripts/verify.sh

# skip the multi-minute subprocess tests (inner loop)
test-fast:
	python -m pytest -x -q -m "not slow"

# one dry-run cell as a launcher smoke check (compiles a 256-chip train
# step against ShapeDtypeStructs; no allocation)
dryrun-smoke:
	PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
	    --shape train_4k
